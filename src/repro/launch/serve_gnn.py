"""GNN serving launcher: Zipfian traffic with phase shifts over a
(dynamically re-tuned) MGG aggregation pipeline.

    PYTHONPATH=src python -m repro.launch.serve_gnn --dataset products \
        --model gcn --dynamic-tune --requests 200 --rotate --burst 4

Reports p50/p99 request latency per phase, the layer-1 cache hit rate,
and the retune trail (tuner audit events) when ``--dynamic-tune`` is on.
``--trace PATH`` writes a Chrome-trace JSON (request lifecycles, tuner
audit instants, and a streamed-pipeline profile pass with per-ring-step
spans and overlap efficiency — load it in ui.perfetto.dev);
``--metrics-json PATH`` writes the metrics snapshot plus the audit trail
machine-readably.  See docs/observability.md.
``--per-layer-tune`` re-optimizes one (ps, dist, pb) per GNN layer
(implies --dynamic-tune); ``--fuse-update`` serves with the dense ·W
update fused into the ring.

``--replicas N`` scales the engine out behind a router
(``--router {load,locality}``, see docs/cluster.md): N independent
serving replicas share one tuned-config cache (``--tune-cache`` or an
auto temp file), stagger their drift retunes through the cluster's
drain → retune → rejoin protocol, and never drop a request.
"""
import os
import sys

if "--devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"
else:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import tempfile

import numpy as np
import jax

import repro.core as C
from repro.dist import flat_ring_mesh
from repro.obs import MetricsRegistry, Tracer, merge_traces
from repro.runtime import DynamicGNNEngine, ProfileConfig
from repro.serve import (GNNServeEngine, ServeCluster, TrafficPhase,
                         WorkloadStats, ZipfTraffic, make_router, run_trace)


def _pct(lat, q):
    return float(np.percentile(np.asarray(lat), q)) if len(lat) else 0.0


def _print_audit(audit, indent="  "):
    """Human view of the tuner audit trail (the machine view goes to
    --metrics-json)."""
    for ev in audit:
        if ev["event"] == "probe":
            continue                       # one line per probe is too chatty
        detail = ", ".join(f"{k}={v}" for k, v in ev.items()
                           if k not in ("event", "measured"))
        print(f"{indent}[{ev['measured']:4d} measured] "
              f"{ev['event']}: {detail}")


def _dump_obs(args, tracer, registry, engines, replica_tracers=None):
    """Write --trace / --metrics-json.  ``engines`` are the serve engines
    whose dynamic runtimes contribute audit trails.  With per-replica
    tracers (``--replicas N --trace``) each replica's events are dumped
    as a JSONL sidecar and folded into ONE Perfetto timeline — the
    cluster (router/drain/rejoin) on its own process row, each replica
    on its own — via :func:`repro.obs.merge_traces`."""
    audits = {f"replica{i}": e.eng.audit
              for i, e in enumerate(engines) if e.dynamic}
    if args.metrics_json:
        registry.dump_json(args.metrics_json, extra={"audit": audits})
        print(f"[serve_gnn] metrics snapshot: {args.metrics_json}")
    if tracer is None or not args.trace:
        return
    if replica_tracers:
        paths, labels = [], []
        for label, t in [("cluster", tracer)] + [
                (f"replica{i}", rt)
                for i, rt in enumerate(replica_tracers)]:
            p = f"{args.trace}.{label}.jsonl"
            t.dump_jsonl(p)
            paths.append(p)
            labels.append(label)
        merge_traces(paths, labels, out=args.trace)
        n = len(tracer) + sum(len(t) for t in replica_tracers)
        print(f"[serve_gnn] merged chrome trace: {args.trace} "
              f"({n} events across {len(paths)} timelines — open in "
              f"ui.perfetto.dev; sidecars: {args.trace}.*.jsonl)")
    else:
        tracer.dump_chrome(args.trace)
        print(f"[serve_gnn] chrome trace: {args.trace} "
              f"({len(tracer)} events — open in ui.perfetto.dev)")


def _profile_pipeline(srv, tracer, passes=3):
    """Run a few streamed aggregations through the live tiered store so
    the trace carries ring-step spans (``mgg.stream.*``) with measured
    overlap efficiency.  Serving's full pass jits the whole forward, so
    per-ring-step host timing is only observable through this explicit
    streamed profile pass — values are identical (fixed-order sum), only
    the schedule is traced."""
    if srv.tiers is None:
        print("[serve_gnn] pipeline profile skipped "
              "(needs --feature-capacity for the tiered streamed path)")
        return
    stats = {}
    for _ in range(passes):
        out = srv.eng.aggregate_streamed(srv.tiers, stats=stats,
                                         tracer=tracer)
        jax.block_until_ready(out)
    print(f"[serve_gnn] pipeline profile: overlap efficiency "
          f"{stats.get('overlap_efficiency', 0.0):.3f} "
          f"(prefetch {stats.get('prefetch_inflight', 0)}/"
          f"{stats.get('prefetch_issued', 0)} in flight)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="products")
    ap.add_argument("--model", default="gcn",
                    choices=["gcn", "gin", "sage", "gat"])
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--requests", type=int, default=200,
                    help="requests per phase")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=1.1)
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--rotate", action="store_true",
                    help="rotate the hot set at the phase boundary")
    ap.add_argument("--burst", type=float, default=1.0,
                    help="phase-2 rate multiplier (burst load)")
    ap.add_argument("--update-frac", type=float, default=0.02)
    ap.add_argument("--dynamic-tune", action="store_true")
    ap.add_argument("--per-layer-tune", action="store_true",
                    help="one (ps, dist, pb) per GNN layer "
                         "(implies --dynamic-tune)")
    ap.add_argument("--fuse-update", action="store_true",
                    help="run the dense ·W update inside the ring")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--feature-capacity", type=int, default=None,
                    help="serve tiered: features live in a host store, "
                         "the device holds only this many hot rows "
                         "(0 = stream everything)")
    ap.add_argument("--frontier-fanout", type=int, default=None,
                    help="bound the stats-side receptive field with a "
                         "sampled k-hop frontier of this per-hop fanout "
                         "(repro.sample); cache gating stays exact")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serving replicas behind the router")
    ap.add_argument("--router", default="locality",
                    choices=["load", "locality"],
                    help="cluster routing policy (--replicas > 1)")
    ap.add_argument("--tune-cache", default=None,
                    help="shared ConfigCache path (replicas warm-start "
                         "each other's retunes through it)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--check-every", type=int, default=8,
                    help="micro-batches between traffic-drift checks")
    ap.add_argument("--stats-window", type=int, default=32,
                    help="WorkloadStats window (smaller = drift-sensitive)")
    ap.add_argument("--min-records", type=int, default=8,
                    help="stats records required before drift checks")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON (open in "
                         "ui.perfetto.dev): request lifecycles, ring-step "
                         "pipeline spans, tuner audit events")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot + tuner "
                         "audit trail as JSON")
    args = ap.parse_args()
    args.dynamic_tune = args.dynamic_tune or args.per_layer_tune

    tracer = Tracer() if args.trace else None
    registry = MetricsRegistry()

    g, meta = C.paper_dataset(args.dataset, scale=args.scale)
    dim = min(int(meta["dim"]), 64)
    ncls = min(int(meta["classes"]), 16)
    x = np.random.default_rng(args.seed).normal(
        size=(g.num_nodes, dim)).astype(np.float32)
    mesh = flat_ring_mesh(len(jax.devices()))

    init, _apply, kw = C.MODEL_ZOO[args.model]
    params = init(jax.random.key(args.seed), dim, ncls, **kw)

    cache_path = args.tune_cache
    if args.dynamic_tune and args.replicas > 1 and cache_path is None:
        # replicas must share ONE cache for cross-replica warm starts
        cache_path = os.path.join(
            tempfile.mkdtemp(prefix="mgg-serve-"), "tuned.json")
        print(f"[serve_gnn] shared config cache: {cache_path}")

    def build_replica(idx=0, rep_tracer=None):
        rtr = rep_tracer if rep_tracer is not None else tracer
        if args.dynamic_tune:
            layer_dims = C.aggregation_widths(args.model, params,
                                              fused=args.fuse_update) \
                if args.per_layer_tune else None
            eng = DynamicGNNEngine.build(
                g, mesh, d_feat=dim,
                ps_space=(1, 2, 4, 8, 16), dist_space=(1, 2, 4),
                pb_space=(1,),
                window=ProfileConfig(warmup=1, iters=2),
                fuse_update=args.fuse_update, layer_dims=layer_dims,
                cache_path=cache_path, log_fn=print,
                tracer=rtr, metrics=registry)
        else:
            eng = C.GNNEngine.build(g, mesh, ps=8, dist=1,
                                    fuse_update=args.fuse_update)
        labels = {"replica": idx} if args.replicas > 1 else {}
        return GNNServeEngine(eng, params, args.model, x, g,
                              slots=args.slots,
                              stats=WorkloadStats(window=args.stats_window),
                              check_every=args.check_every,
                              min_records=args.min_records,
                              use_cache=not args.no_cache,
                              feature_capacity=args.feature_capacity,
                              frontier_fanout=args.frontier_fanout,
                              frontier_seed=args.seed + idx,
                              log_fn=print, tracer=rtr,
                              metrics=registry, obs_labels=labels)

    phases = [
        TrafficPhase(requests=args.requests, alpha=args.alpha,
                     rate=args.rate, seeds_max=min(4, args.slots),
                     update_frac=args.update_frac),
        TrafficPhase(requests=args.requests, alpha=args.alpha,
                     rate=args.rate * args.burst, rotate=args.rotate,
                     seeds_max=min(4, args.slots),
                     update_frac=args.update_frac),
    ]
    traffic = ZipfTraffic(g.num_nodes, dim, phases, seed=args.seed)

    if args.replicas > 1:
        # each replica records onto its OWN tracer (pid = replica index +
        # 1; the cluster keeps pid 0) so the dump can merge N replica
        # timelines into one Perfetto view with distinct process rows
        rep_tracers = ([Tracer(pid=i + 1) for i in range(args.replicas)]
                       if tracer is not None else None)
        replicas = [build_replica(i, rep_tracers[i] if rep_tracers else None)
                    for i in range(args.replicas)]
        cluster = ServeCluster(replicas, router=make_router(args.router),
                               log_fn=print, tracer=tracer,
                               metrics=registry)
        results = cluster.run_trace(traffic)
        lat = [r.latency for r in results]
        rep = cluster.report()
        print(f"cluster: {rep['replicas']} replicas, "
              f"router={rep['router']}, served {rep['served']} "
              f"(dropped {rep['dropped']}, shadow {rep['shadow_served']})")
        print(f"latency p50 {_pct(lat, 50) * 1e3:.2f} ms  "
              f"p99 {_pct(lat, 99) * 1e3:.2f} ms")
        print(f"staggered retunes {rep['staggered_retunes']} "
              f"(deferred {rep['deferred_retunes']})")
        for entry in rep["retune_log"]:
            print(f"  {entry}")
        for i, p in enumerate(rep["per_replica"]):
            print(f"  replica {i}: served {p['served']}, hit rate "
                  f"{p['cache_hit_rate']:.3f}, retunes {p['retunes']}, "
                  f"config {p['config']}")
        if any(p.get("tiers") for p in rep["per_replica"]):
            print(f"tiered features (cluster): "
                  f"{rep['host_rows_streamed']} rows streamed from host, "
                  f"{rep['cache_rows_served']} rows served from device cache")
            for i, p in enumerate(rep["per_replica"]):
                t = p.get("tiers")
                if t:
                    print(f"  replica {i}: cap {t['capacity']} rows "
                          f"({t['resident_fraction']:.1%} resident), "
                          f"feature hit rate {t['hit_rate']:.3f}")
        if args.dynamic_tune:
            for i, r in enumerate(replicas):
                if r.dynamic and r.eng.audit:
                    print(f"  replica {i} audit trail:")
                    _print_audit(r.eng.audit, indent="    ")
        if tracer is not None:
            _profile_pipeline(replicas[0], rep_tracers[0])
        _dump_obs(args, tracer, registry, replicas,
                  replica_tracers=rep_tracers)
        return

    srv = build_replica()
    results = run_trace(srv, traffic)
    lat = [r.latency for r in results]
    rep = srv.report()
    print(f"served {rep['served']} requests over {rep['batches']} "
          f"micro-batches (dropped {rep['dropped']})")
    print(f"latency p50 {_pct(lat, 50) * 1e3:.2f} ms  "
          f"p99 {_pct(lat, 99) * 1e3:.2f} ms")
    print(f"cache hit rate {rep['cache_hit_rate']:.3f} "
          f"({rep['cache_stores']} stores, "
          f"{rep['cache_invalidations']} invalidations)")
    if rep["tiers"] is not None:
        t = rep["tiers"]
        print(f"tiered features: cap {t['capacity']} rows "
              f"({t['resident_fraction']:.1%} resident), feature hit rate "
              f"{t['hit_rate']:.3f}, streamed "
              f"{t['host_bytes_streamed'] / 1e6:.1f} MB from host")
    if args.dynamic_tune:
        print(f"retunes {rep['retunes']}, rebuilds {rep['rebuilds']}, "
              f"final config {rep['config']}")
        # retune trail, straight from the tuner audit events (the same
        # records --metrics-json captures machine-readably)
        _print_audit(srv.eng.audit)
    if tracer is not None:
        _profile_pipeline(srv, tracer)
    _dump_obs(args, tracer, registry, [srv])


if __name__ == "__main__":
    main()
