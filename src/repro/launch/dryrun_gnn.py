import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first lines, before any jax import (same contract as dryrun.py)

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

"""GNN-engine dry-run on the production mesh: the paper's own workload
(pipelined ring aggregation for a GCN layer) lowered + compiled across 256
(single-pod) or 512 (multi-pod) chips, with roofline terms.

The ring spans the flattened mesh (DESIGN.md §7: neighbor hops on a torus).
Graph: the reddit structural stand-in; the plan is built host-side exactly
as in production (Alg.1 → locality split → ring-step bucketing), inputs are
ShapeDtypeStructs — no device allocation.

    PYTHONPATH=src python -m repro.launch.dryrun_gnn [--chips 512] [--dim 602]
"""

from repro.core import build_plan, paper_dataset, collective_bytes  # noqa: E402
from repro.core.pipeline import mgg_aggregate, plan_device_arrays  # noqa: E402
from repro.launch.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.core.autotune import TPU_V5E  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=256, choices=(256, 512))
    ap.add_argument("--dim", type=int, default=602)   # reddit embedding dim
    ap.add_argument("--ps", type=int, default=16)
    ap.add_argument("--dist", type=int, default=1)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    g, meta = paper_dataset("reddit", scale=args.scale)
    t0 = time.time()
    plan = build_plan(g, args.chips, ps=args.ps, dist=args.dist)
    t_plan = time.time() - t0
    mesh = jax.make_mesh((args.chips,), ("ring",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x_abs = jax.ShapeDtypeStruct(
        (plan.padded_nodes, args.dim), jnp.float32)
    arrays_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        plan_device_arrays(plan))

    def agg(x, arrays):
        from repro.core import pipeline as pp
        import functools
        body = functools.partial(
            pp._mgg_shard_body, axis_name="ring", n_dev=plan.n_dev,
            dist=plan.dist, tile_rows=plan.tile_rows, interleave=True,
            use_kernel=False, acc_dtype=jnp.float32)
        from jax.sharding import PartitionSpec as P
        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P("ring"), pp._plan_specs("ring")),
            out_specs=P("ring"), check_vma=False)
        return fn(x, arrays)

    with mesh:
        t0 = time.time()
        lowered = jax.jit(agg).lower(x_abs, arrays_abs)
        compiled = lowered.compile()
        t_compile = time.time() - t0
    tc = hlo_analyze(compiled.as_text())
    hw = TPU_V5E
    t_comp = tc.dot_flops / hw.peak_flops
    t_mem = tc.bytes_accessed / hw.hbm_bw
    t_coll = tc.total_collective_bytes / hw.link_bw
    result = dict(
        arch="gnn-reddit-gcn-aggregate", shape=f"dim{args.dim}",
        mesh=f"ring{args.chips}", n_chips=args.chips,
        nodes=g.num_nodes, edges=g.num_edges,
        plan_build_s=round(t_plan, 2), compile_s=round(t_compile, 2),
        flops=tc.dot_flops, bytes_accessed=tc.bytes_accessed,
        collectives=tc.as_dict(),
        model_collective_bytes=collective_bytes(plan, args.dim),
        terms=dict(compute=t_comp, memory=t_mem, collective=t_coll),
    )
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(
            args.out, f"gnn_reddit_ring{args.chips}.json"), "w") as f:
        json.dump(result, f, indent=1)
    print(json.dumps({k: v for k, v in result.items()
                      if k != "collectives"}, indent=1))
    print("collectives:", json.dumps(result["collectives"]["per_op"]))


if __name__ == "__main__":
    main()
