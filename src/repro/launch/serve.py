"""Serving launcher: batched generation over request files or synthetic
prompts.

    PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b \
        --smoke --requests 8 --max-new 32
"""
import os
import sys

if "--devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

import argparse
import time

import numpy as np
import jax

from repro import configs
from repro.models import transformer as T
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--devices", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    params = T.init_params(jax.random.key(0), cfg, vocab_multiple=16)
    eng = ServeEngine(params, cfg, batch_slots=args.batch_slots,
                      max_seq=512)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=rng.integers(4, 17))
               .astype(np.int32) for _ in range(args.requests)]
    t0 = time.perf_counter()
    res = eng.generate(prompts, max_new=args.max_new,
                       temperature=args.temperature)
    dt = time.perf_counter() - t0
    tok = sum(r.steps for r in res)
    print(f"{len(res)} requests, {tok} tokens, {dt:.2f}s "
          f"({tok/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
