"""(architecture × input-shape × mesh) cell builder for the dry-run.

For every cell this produces:
  * the step callable (train_step / prefill / decode_step per shape.kind),
  * abstract arguments (ShapeDtypeStructs — weak-type-correct, shardable,
    zero device allocation),
  * in/out shardings derived from dist/sharding.py rules,
so launch/dryrun.py can ``jit(...).lower(*args).compile()`` and
benchmarks/roofline.py can reuse the identical lowering.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import SHAPES, ModelConfig, ShapeSpec, shape_applicable
from repro.dist import sharding as shd
from repro.models import encdec, transformer
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step

N_FRAMES = 1500  # whisper stub frontend output length


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    fn: Callable
    args: Tuple[Any, ...]           # abstract ShapeDtypeStructs
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]
    cfg: ModelConfig
    meta: Dict[str, Any]


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = dict(tokens=_sds((b, s), jnp.int32),
                     loss_mask=_sds((b, s), jnp.float32))
        if cfg.family == "vlm":
            batch["vis"] = _sds((b, cfg.n_vis_tokens, cfg.d_model),
                                jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = _sds((b, N_FRAMES, cfg.d_model), jnp.float32)
        return batch
    if shape.kind == "prefill":
        out = dict(tokens=_sds((b, s), jnp.int32))
        if cfg.family == "vlm":
            out["vis"] = _sds((b, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            out["frames"] = _sds((b, N_FRAMES, cfg.d_model), jnp.float32)
        return out
    return dict(token=_sds((b,), jnp.int32), pos=_sds((b,), jnp.int32))


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               *, multi_pod: bool = False,
               moe_pipeline_chunks: int = 1,
               extra_cfg: Optional[dict] = None,
               fsdp: bool = True,
               shard_acts: bool = True,
               seq_shard_acts: Optional[bool] = None) -> Cell:
    cfg = configs.get_config(arch)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} × {shape_name} skipped: {why}")
    data_axes = ("pod", "data") if multi_pod else ("data",)
    train = shape.kind == "train"
    # serve uses bf16 parameters; train keeps fp32 masters (DESIGN.md §7)
    if not train:
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    vocab_mult = mesh.shape["model"]
    if seq_shard_acts is None:
        # recurrent families reshard the sequence dim inside their scans —
        # batch-only activation sharding for them (EXPERIMENTS.md §Perf)
        seq_shard_acts = cfg.family not in ("xlstm", "hybrid")
    ctx = transformer.DistCtx(
        mesh=mesh, data_axes=data_axes,
        moe_pipeline_chunks=moe_pipeline_chunks,
        # batch must divide the data axes to shard activations on them
        shard_activations=shard_acts and shape.global_batch % int(
            np.prod([mesh.shape[a] for a in data_axes])) == 0,
        seq_shard_acts=seq_shard_acts,
    )
    rules = shd.ShardingRules(mesh, data_axes=data_axes,
                              train=train and fsdp)
    init = (encdec.init_params if cfg.family == "encdec"
            else transformer.init_params)
    params_abs = jax.eval_shape(
        functools.partial(init, cfg=cfg, vocab_multiple=vocab_mult),
        jax.random.key(0))
    p_specs = shd.param_specs(params_abs, rules, cfg.expert_mode)
    p_shard = shd.to_shardings(p_specs, mesh)
    meta = dict(arch=arch, shape=shape_name, kind=shape.kind,
                multi_pod=multi_pod, params=cfg.param_count())

    if train:
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        o_specs = dict(m=p_specs, v=p_specs, count=P())
        o_shard = shd.to_shardings(o_specs, mesh)
        batch_abs = input_specs(cfg, shape)
        b_specs = shd.batch_specs(batch_abs, rules)
        b_shard = shd.to_shardings(b_specs, mesh)
        step = make_train_step(cfg, ctx, AdamWConfig())
        return Cell(arch, shape, step, (params_abs, opt_abs, batch_abs),
                    (p_shard, o_shard, b_shard), (0, 1), cfg, meta)

    if shape.kind == "prefill":
        inp = input_specs(cfg, shape)
        if cfg.family == "encdec":
            cache_abs = jax.eval_shape(
                lambda: encdec.init_cache(cfg, shape.global_batch,
                                          shape.seq_len, N_FRAMES))
            fn = lambda p, frames, tokens, c: encdec.prefill(
                p, cfg, frames, tokens, c, ctx=ctx)
            args = (params_abs, inp["frames"], inp["tokens"], cache_abs)
        else:
            cache_abs = jax.eval_shape(
                lambda: transformer.init_cache(cfg, shape.global_batch,
                                               shape.seq_len))
            if cfg.family == "vlm":
                fn = lambda p, tokens, vis, c: transformer.prefill(
                    p, cfg, tokens, c, ctx=ctx, vis=vis)
                args = (params_abs, inp["tokens"], inp["vis"], cache_abs)
            else:
                fn = lambda p, tokens, c: transformer.prefill(
                    p, cfg, tokens, c, ctx=ctx)
                args = (params_abs, inp["tokens"], cache_abs)
        c_specs = shd.cache_specs(cache_abs, rules, shape.global_batch)
        c_shard = shd.to_shardings(c_specs, mesh)
        in_sh = [p_shard] + [
            shd.to_shardings(shd.batch_specs(a, rules), mesh)
            for a in args[1:-1]
        ] + [c_shard]
        return Cell(arch, shape, fn, args, tuple(in_sh),
                    (len(args) - 1,), cfg, meta)

    # decode
    inp = input_specs(cfg, shape)
    if cfg.family == "encdec":
        cache_abs = jax.eval_shape(
            lambda: encdec.init_cache(cfg, shape.global_batch,
                                      shape.seq_len, N_FRAMES))
        fn = lambda p, t, pos, c: encdec.decode_step(p, cfg, t, pos, c,
                                                     ctx=ctx)
    else:
        cache_abs = jax.eval_shape(
            lambda: transformer.init_cache(cfg, shape.global_batch,
                                           shape.seq_len))
        fn = lambda p, t, pos, c: transformer.decode_step(
            p, cfg, t, pos, c, ctx=ctx)
    args = (params_abs, inp["token"], inp["pos"], cache_abs)
    c_specs = shd.cache_specs(cache_abs, rules, shape.global_batch)
    in_sh = (p_shard,
             shd.to_shardings(shd.batch_specs(inp["token"], rules), mesh),
             shd.to_shardings(shd.batch_specs(inp["pos"], rules), mesh),
             shd.to_shardings(c_specs, mesh))
    return Cell(arch, shape, fn, args, in_sh, (3,), cfg, meta)


def all_cells() -> list:
    """Every runnable (arch × shape) pair + the documented skips."""
    run, skipped = [], []
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            (run if ok else skipped).append(
                (arch, sname) if ok else (arch, sname, why))
    return run, skipped
