import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
# init).  512 placeholder host devices back both the single-pod (16×16)
# and the multi-pod (2×16×16) production meshes.  Do NOT set this globally:
# smoke tests and benches must see 1 device.

import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.launch.cells import build_cell, all_cells
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh

"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers,
SPMD-partitions, and compiles on the production topology, and extract the
artifacts (FLOPs, bytes, per-device collective traffic, memory analysis)
that feed EXPERIMENTS.md §Dry-run and §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
"""

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s/#:]+?)\s+"
    r"([\w\-]+)\(([^)]*)\)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` as a flat dict.

    Older jaxlibs return a one-element list of per-module dicts; current
    ones return the dict directly.  Normalize so callers can ``.get``.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective traffic from the partitioned HLO.

    Sums *operand* bytes of every collective op (the data each device
    injects into the interconnect).  ``-start`` async forms are counted;
    their ``-done`` halves are skipped (same transfer).
    """
    defs: Dict[str, int] = {}
    per_op: Dict[str, Dict[str, float]] = {}
    n_async = 0
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op, operands = m.groups()
        defs[name] = _shape_bytes(type_str)
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        if op.endswith("-start"):
            n_async += 1
        # operand bytes: resolve %name refs against prior defs
        op_bytes = 0
        for ref in re.findall(r"%?([\w.\-]+)", operands):
            if ref in defs:
                op_bytes += defs[ref]
        if op_bytes == 0:  # fallback: estimate from result size
            res = _shape_bytes(type_str)
            op_bytes = res
        d = per_op.setdefault(base, dict(bytes=0.0, count=0))
        d["bytes"] += op_bytes
        d["count"] += 1
    total = sum(d["bytes"] for d in per_op.values())
    return dict(per_op=per_op, total_bytes=total, n_async=n_async)


def run_cell(arch: str, shape: str, multi_pod: bool,
             out_dir: Optional[str] = None,
             moe_pipeline_chunks: int = 1,
             extra_cfg: Optional[dict] = None,
             tag: str = "",
             fsdp: bool = True,
             shard_acts: bool = True,
             seq_shard_acts: Optional[bool] = None) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, multi_pod=multi_pod,
                      moe_pipeline_chunks=moe_pipeline_chunks,
                      extra_cfg=extra_cfg, fsdp=fsdp, shard_acts=shard_acts,
                      seq_shard_acts=seq_shard_acts)
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    try:
        mem = compiled.memory_analysis()
        mem_info = dict(
            argument_size=getattr(mem, "argument_size_in_bytes", None),
            output_size=getattr(mem, "output_size_in_bytes", None),
            temp_size=getattr(mem, "temp_size_in_bytes", None),
            generated_code_size=getattr(mem, "generated_code_size_in_bytes",
                                        None),
        )
    except Exception as e:
        mem_info = dict(error=str(e))
    try:
        cost = cost_analysis_dict(compiled)
        cost_info = {k: float(v) for k, v in cost.items()
                     if isinstance(v, (int, float)) and (
                         "flops" in k or "bytes accessed" in k
                         or k in ("utilization", "optimal_seconds"))}
        flops = float(cost.get("flops", 0.0))
        bytes_accessed = float(cost.get("bytes accessed", 0.0))
    except Exception as e:
        cost_info, flops, bytes_accessed = dict(error=str(e)), 0.0, 0.0
    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text)
    # Trip-count-aware reanalysis: XLA's cost_analysis counts while bodies
    # once; every scanned layer/chunk loop must be multiplied out
    # (launch/hlo_cost.py, oracle-tested).  These corrected numbers are the
    # roofline numerators; the raw XLA values are kept for reference.
    tc = hlo_analyze(hlo_text)
    n_chips = int(np.prod(list(mesh.shape.values())))
    result = dict(
        arch=arch, shape=shape, mesh="multi_pod" if multi_pod else
        "single_pod", n_chips=n_chips, kind=cell.shape.kind,
        model_params=cell.meta["params"],
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        flops=tc.dot_flops, bytes_accessed=tc.bytes_accessed,
        collectives=tc.as_dict(),
        xla_raw=dict(flops=flops, bytes_accessed=bytes_accessed,
                     cost=cost_info, collectives=coll),
        memory=mem_info,
        moe_pipeline_chunks=moe_pipeline_chunks, tag=tag,
    )
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = os.path.join(
            out_dir, f"{arch}_{shape}_{result['mesh']}{suffix}.json")
        with open(fname, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=False)
    ap.add_argument("--shape", required=False)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--moe-pipeline-chunks", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-shard-acts", action="store_true")
    ap.add_argument("--seq-shard-acts", default="auto",
                    choices=["auto", "on", "off"])
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--param-dtype", default="")
    args = ap.parse_args()
    knobs = dict(
        fsdp=not args.no_fsdp, shard_acts=not args.no_shard_acts,
        seq_shard_acts={"auto": None, "on": True, "off": False}[
            args.seq_shard_acts])
    extra = {}
    if args.capacity_factor:
        extra["moe_capacity_factor"] = args.capacity_factor
    if args.param_dtype:
        extra["param_dtype"] = args.param_dtype

    if args.all:
        run, skipped = all_cells()
        for arch, shape in run:
            for mp in ((False, True) if args.both_meshes
                       else (args.multi_pod,)):
                r = run_cell(arch, shape, mp, args.out,
                             args.moe_pipeline_chunks, extra_cfg=extra or None,
                             tag=args.tag, **knobs)
                print(f"{arch} × {shape} × {r['mesh']}: OK "
                      f"flops={r['flops']:.3e} "
                      f"coll={r['collectives']['total_bytes']:.3e}B "
                      f"compile={r['compile_s']}s")
        for arch, shape, why in skipped:
            print(f"{arch} × {shape}: SKIP ({why})")
        return

    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for mp in meshes:
        r = run_cell(args.arch, args.shape, mp, args.out,
                     args.moe_pipeline_chunks, extra_cfg=extra or None,
                     tag=args.tag, **knobs)
        print(json.dumps(
            {k: r[k] for k in ("arch", "shape", "mesh", "n_chips", "flops",
                               "bytes_accessed", "lower_s", "compile_s")},
            indent=1))
        print("memory:", r["memory"])
        print("collectives:", json.dumps(r["collectives"], indent=1))


if __name__ == "__main__":
    main()
