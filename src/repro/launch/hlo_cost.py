"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, so any
scan-over-layers model (ours: every assigned arch) under-reports FLOPs,
bytes, and — critically for MGG — the collective traffic of loops like the
ppermute ring or the per-layer MoE all-to-all.  (Verified: an 8-step scanned
matmul chain reports 1/8 the unrolled FLOPs.)

This module re-derives the three roofline numerators from the *partitioned*
HLO text with loop multiplicities:

1. parse computations (name → {op defs, param shapes});
2. build the call graph: ``while`` edges carry their trip count (read from
   the loop-condition computation's s32 ``constant``), ``calls=`` /
   ``to_apply=`` / ``condition=`` edges carry ×1;
3. propagate multipliers from ENTRY and accumulate per-computation:
   * **dot FLOPs** — 2 · numel(result) · contraction size (the MXU term;
     elementwise flops are ignored, they are never roofline-critical),
   * **bytes** — Σ over ops (operand bytes + result bytes), an HBM-traffic
     upper bound (fusion on real TPUs reduces it; stated in EXPERIMENTS.md),
   * **collectives** — operand bytes per op type, trip-multiplied, with
     async ``-start`` counting.

The analyzer is oracle-tested against unrolled references in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze", "HLOCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],{}]+))")
_CALL_RE = re.compile(
    r"(?:calls|to_apply|condition|body|true_computation|false_computation)"
    r"=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    op: str
    rest: str  # operand list + attributes


@dataclasses.dataclass
class _Comp:
    name: str
    params: Dict[str, str]
    ops: List[_Op]
    shapes: Dict[str, str]  # def/param name → type string


@dataclasses.dataclass
class HLOCost:
    dot_flops: float
    bytes_accessed: float
    collectives: Dict[str, Dict[str, float]]
    total_collective_bytes: float
    n_async: int
    while_trips: Dict[str, int]

    def as_dict(self) -> Dict:
        return dict(
            dot_flops=self.dot_flops, bytes_accessed=self.bytes_accessed,
            per_op=self.collectives,
            total_bytes=self.total_collective_bytes, n_async=self.n_async,
            while_trips=self.while_trips,
        )


def _parse_computations(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.endswith("{"):
                name, params_str = m.group(1), m.group(2)
                params = {p: t for p, t in _PARAM_RE.findall(params_str)}
                cur = _Comp(name, params, [], dict(params))
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = _Op(*m.groups())
            cur.ops.append(op)
            cur.shapes[op.name] = op.type_str
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands live before the closing paren of the op call
    depth, end = 1, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w.\-]+)", rest[:end])


def _dot_flops(op: _Op, comp: _Comp) -> float:
    result = 1
    for _, dims in _shape_dims(op.type_str):
        for d in dims:
            result *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if m:
        ops = _operand_names(op.rest)
        if ops:
            lhs_type = comp.shapes.get(ops[0], "")
            dims_list = _shape_dims(lhs_type)
            if dims_list:
                lhs_dims = dims_list[0][1]
                for idx in (int(i) for i in m.group(1).split(",") if i):
                    if idx < len(lhs_dims):
                        contract *= lhs_dims[idx]
    return 2.0 * result * contract


def _trip_count(cond: _Comp, comps: Dict[str, _Comp]) -> int:
    """Largest s32 constant reachable in the condition computation."""
    best = 1
    stack, seen = [cond.name], set()
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in comps:
            continue
        seen.add(cname)
        for op in comps[cname].ops:
            if op.op == "constant" and op.type_str.strip().startswith("s32"):
                m = re.search(r"constant\((-?\d+)\)", f"constant({op.rest}")
                if m:
                    best = max(best, int(m.group(1).rstrip(")")))
            for callee in _CALL_RE.findall(op.rest):
                stack.append(callee)
    return max(best, 1)


def analyze(text: str, entry: Optional[str] = None) -> HLOCost:
    comps = _parse_computations(text)
    if not comps:
        return HLOCost(0.0, 0.0, {}, 0.0, 0, {})
    # entry = computation that no one calls, or explicit
    called = set()
    for c in comps.values():
        for op in c.ops:
            for callee in _CALL_RE.findall(op.rest):
                called.add(callee)
            m = _BRANCHES_RE.search(op.rest)
            if m:
                called.update(re.findall(r"%?([\w.\-]+)", m.group(1)))
    entries = [n for n in comps if n not in called]
    root = entry or (entries[-1] if entries else next(iter(comps)))

    flops = 0.0
    bytes_acc = 0.0
    coll: Dict[str, Dict[str, float]] = {}
    n_async = 0
    trips: Dict[str, int] = {}
    visited_stack = set()

    def visit(cname: str, mult: float, count_bytes: bool = True) -> None:
        nonlocal flops, bytes_acc, n_async
        if cname not in comps or cname in visited_stack:
            return
        visited_stack.add(cname)
        comp = comps[cname]
        for op in comp.ops:
            res_bytes = _shape_bytes(op.type_str)
            opd_bytes = sum(_shape_bytes(comp.shapes.get(o, ""))
                            for o in _operand_names(op.rest))
            if count_bytes and op.op not in (
                    "parameter", "constant", "tuple",
                    "get-tuple-element", "bitcast"):
                # fusion ops count at their boundary (operands + result);
                # their internals model registers/VMEM, not HBM traffic
                bytes_acc += mult * (res_bytes + opd_bytes)
            if op.op in ("dot", "dot_general"):
                flops += mult * _dot_flops(op, comp)
            base = op.op[:-6] if op.op.endswith("-start") else op.op
            if base in _COLLECTIVES and not op.op.endswith("-done"):
                if op.op.endswith("-start"):
                    n_async += int(mult)
                d = coll.setdefault(base, dict(bytes=0.0, count=0.0))
                d["bytes"] += mult * (opd_bytes or res_bytes)
                d["count"] += mult
            # traverse callees
            if op.op == "while":
                m_body = re.search(r"body=%?([\w.\-]+)", op.rest)
                m_cond = re.search(r"condition=%?([\w.\-]+)", op.rest)
                trip = 1
                if m_cond and m_cond.group(1) in comps:
                    trip = _trip_count(comps[m_cond.group(1)], comps)
                    trips[m_body.group(1) if m_body else op.name] = trip
                    visit(m_cond.group(1), mult * trip, count_bytes)
                if m_body:
                    visit(m_body.group(1), mult * trip, count_bytes)
            elif op.op == "conditional":
                m = _BRANCHES_RE.search(op.rest)
                branches = (re.findall(r"%?([\w.\-]+)", m.group(1))
                            if m else _CALL_RE.findall(op.rest))
                for b2 in branches:
                    visit(b2, mult, count_bytes)
            elif op.op == "fusion":
                # dots/collectives inside fusions still count (flops);
                # bytes stop at the fusion boundary
                for callee in _CALL_RE.findall(op.rest):
                    visit(callee, mult, False)
            else:
                for callee in _CALL_RE.findall(op.rest):
                    visit(callee, mult, count_bytes)
        visited_stack.discard(cname)

    visit(root, 1.0)
    total = sum(d["bytes"] for d in coll.values())
    return HLOCost(flops, bytes_acc, coll, total, n_async, trips)
